"""Kernel micro-benchmarks: us/call of the three Pallas kernels (interpret
mode on this CPU rig; the numbers are CI-tracking, not TPU projections) and
of the MonarchKVIndex batched prefix lookup — the device-resident CAM fast
path (one fused multi-set launch per batch).  Timing discipline (warmup,
median-of-k, block_until_ready) comes from ``repro.bench.harness``.

``benchmarks/check_regression.py`` compares the emitted medians against the
committed ``benchmarks/baselines/BENCH_kernels.json``.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

import jax

from repro.bench import (BenchSizes, emit_json, time_callable,
                         time_interleaved)
from repro.core import wear
from repro.kernels.common import pack_bits_np
from repro.kernels.hopscotch import ops as hop_ops
from repro.kernels.string_match import ops as sm_ops
from repro.kernels.xam_search import ops as xam_ops
from repro.serve.admit_queue import AdmitQueue
from repro.serve.kv_index import (KVIndexConfig, MonarchKVIndex,
                                  _install_column)


def _admit_hostloop(idx: MonarchKVIndex, fps: np.ndarray):
    """The pre-batching admission flow (PR 2's `_admit_one` loop): one
    jitted install dispatch + per-fingerprint host bookkeeping PER
    fingerprint.  Kept here as the measured comparator for the O(1)-call
    batched pipeline (`kv_index_admit` vs `kv_index_admit_hostloop`)."""
    for fp in fps:
        s = int(idx._set_of(np.asarray([fp], np.uint32))[0])
        free = np.nonzero(~idx.valid_np[s])[0]
        w = int(free[0]) if free.size else 0
        bitcol = jnp.asarray(xam_ops.words_to_bits_np(
            np.asarray([fp], np.uint32), idx.cfg.key_bits)[0])
        idx.bits, idx.valid, idx.fp_of = _install_column(
            idx.bits, idx.valid, idx.fp_of,
            jnp.int32(s), jnp.int32(w), bitcol, jnp.uint32(fp))
        idx.valid_np[s, w] = True
        idx.slot_of[int(fp)] = (s, w)
    jax.block_until_ready(idx.valid)


def run(csv_rows: list[str], quick: bool = False):
    rng = np.random.default_rng(0)
    reps = BenchSizes(quick=quick).kernel_reps
    print("\n== kernel micro-benchmarks (CPU interpret mode) ==")
    timings = {}

    keys = rng.integers(0, 2, (64, 64)).astype(np.int8)
    data = rng.integers(0, 2, (64, 512)).astype(np.int8)
    t = time_callable(lambda: xam_ops.xam_search(keys, data), reps=reps)
    timings["xam_search"] = t
    print(f"xam_search 64q x (64x512): {t.median_us:.0f} us")
    csv_rows.append(f"kernel_xam_search,{t.median_us:.0f},64x512")

    # fused multi-set search: 128 queries over 8 device-resident planes
    n_sets, r, c = 8, 32, 512
    planes = jnp.asarray(rng.integers(0, 2, (n_sets, r, c)).astype(np.int8))
    valid = jnp.asarray(rng.integers(0, 2, (n_sets, c)).astype(np.int8))
    m_words = rng.integers(0, 2 ** 32, 128, dtype=np.uint32)
    m_sets = rng.integers(0, n_sets, 128).astype(np.int32)
    m_bits = xam_ops.words_to_bits_np(m_words, r)

    # The int8 and PACKED (plane_format="packed8": 8 bits per uint8 word
    # along R, unpacked in VMEM per tile) variants of the same workload.
    # Results are bit-identical, plane traffic is 8x lower;
    # check_regression.py gates the packed median against both the
    # same-run int8 median and the committed baseline, so the pair is
    # timed INTERLEAVED with a higher rep floor than the rest of the
    # quick sweep — at reps=3 back-to-back, interpret-mode medians
    # wobble ~20% run-to-run, more than the packed win being gated.
    planes_packed = jnp.asarray(pack_bits_np(np.asarray(planes), axis=1))
    out_p = xam_ops.xam_search_multiset(m_bits, m_sets, planes_packed, valid)
    out_i = xam_ops.xam_search_multiset(m_bits, m_sets, planes, valid)
    assert np.array_equal(np.asarray(out_p), np.asarray(out_i)), \
        "packed planes must be bit-identical to int8 planes"
    t, tp = time_interleaved(
        [lambda: xam_ops.xam_search_multiset(m_bits, m_sets, planes, valid),
         lambda: xam_ops.xam_search_multiset(
             m_bits, m_sets, planes_packed, valid)],
        warmup=3, reps=max(reps, 11))
    timings["xam_multiset"] = t
    print(f"xam_multiset 128q x 8 sets (32x512): {t.median_us:.0f} us")
    csv_rows.append(f"kernel_xam_multiset,{t.median_us:.0f},8x32x512")
    timings["xam_multiset_packed"] = tp
    print(f"xam_multiset_packed 128q x 8 sets (4x512 words): "
          f"{tp.median_us:.0f} us -> {t.median_us / tp.median_us:.2f}x vs "
          f"int8 planes (bit-identical)")
    csv_rows.append(f"kernel_xam_multiset_packed,{tp.median_us:.0f},"
                    f"8x4x512w")

    h, n = 32, 32 * 256
    t_lo = rng.integers(0, 2 ** 32, n, dtype=np.uint32)
    t_hi = rng.integers(0, 2 ** 32, n, dtype=np.uint32)
    homes = rng.integers(0, n - 2 * h, 64).astype(np.int32)
    q = rng.integers(0, 2 ** 32, 64, dtype=np.uint32)
    t = time_callable(
        lambda: hop_ops.hopscotch_lookup(t_lo, t_hi, homes, q, q, window=h),
        reps=reps)
    timings["hopscotch_lookup"] = t
    print(f"hopscotch_lookup 64q w32: {t.median_us:.0f} us")
    csv_rows.append(f"kernel_hopscotch,{t.median_us:.0f},w32")

    text = rng.integers(97, 113, 1 << 16).astype(np.uint8)
    pat = text[1000:1012].copy()
    t = time_callable(lambda: sm_ops.string_match(text, pat, tile=4096),
                      reps=reps)
    timings["string_match"] = t
    print(f"string_match 64KiB p12: {t.median_us:.0f} us")
    csv_rows.append(f"kernel_string_match,{t.median_us:.0f},64KiB")

    idx = MonarchKVIndex(KVIndexConfig(n_sets=8))
    toks = rng.integers(1, 1000, (4, 256)).astype(np.int32)
    idx.admit(toks)
    idx.admit(toks)   # second touch -> admitted
    t = time_callable(lambda: idx.lookup(toks), warmup=1, reps=reps)
    timings["kv_index_lookup"] = t
    print(f"kv_index lookup 4x256 tokens: {t.median_us:.0f} us "
          f"(hit rate {idx.hit_rate:.2f}, "
          f"{idx.stats.searches} launches/{idx.stats.lookups} lookups)")
    csv_rows.append(f"kv_index_lookup,{t.median_us:.0f},{idx.hit_rate:.2f}")

    # batch scaling: one launch regardless of batch width
    toks_big = rng.integers(1, 4000, (32, 512)).astype(np.int32)
    idx.admit(toks_big)
    idx.admit(toks_big)
    t = time_callable(lambda: idx.lookup(toks_big), warmup=1, reps=reps)
    timings["kv_index_lookup_32x512"] = t
    print(f"kv_index lookup 32x512 tokens: {t.median_us:.0f} us "
          f"({t.median_us / (32 * 512 // 16):.1f} us/chunk)")
    csv_rows.append(f"kv_index_lookup_32x512,{t.median_us:.0f},")

    # set-sharded lookup: same 32x512 batch at n_shards=4, now ONE device
    # dispatch regardless of the shard count (the stacked shard_map path
    # on a ("sets",) mesh; collapsed to the single fused launch on this
    # 1-device rig — either way the per-shard host fan-out is gone, which
    # is what the number tracks vs the PR-4 baseline).
    idx_s = MonarchKVIndex(KVIndexConfig(n_sets=8, n_shards=4))
    idx_s.admit(toks_big)
    idx_s.admit(toks_big)
    t = time_callable(lambda: idx_s.lookup(toks_big), warmup=1, reps=reps)
    timings["kv_index_lookup_sharded"] = t
    print(f"kv_index lookup 32x512 tokens, 4 set shards "
          f"({idx_s.n_parts} partitions): {t.median_us:.0f} us "
          f"({idx_s.stats.searches} dispatches/"
          f"{idx_s.stats.lookups} lookups)")
    csv_rows.append(f"kv_index_lookup_sharded,{t.median_us:.0f},4shards")

    # the kept PR-4 host fan-out (differential reference): one pallas_call
    # per occupied shard — the measured comparator for the single dispatch
    idx_f = MonarchKVIndex(KVIndexConfig(n_sets=8, n_shards=4),
                           dispatch="fanout")
    idx_f.admit(toks_big)
    idx_f.admit(toks_big)
    t2 = time_callable(lambda: idx_f.lookup(toks_big), warmup=1, reps=reps)
    timings["kv_index_lookup_fanout"] = t2
    print(f"kv_index lookup 32x512 tokens, 4-shard host fan-out: "
          f"{t2.median_us:.0f} us -> single-dispatch speedup "
          f"{t2.median_us / t.median_us:.1f}x")
    csv_rows.append(f"kv_index_lookup_fanout,{t2.median_us:.0f},"
                    f"{t2.median_us / t.median_us:.1f}x")

    # device-resident rotation: the set+7 remap (donated roll + ppermute
    # boundary exchange across partitions; pure donated roll when
    # collapsed) — plane data never moves through the host.
    t = time_callable(lambda: idx_s._rotate(), warmup=1, reps=reps)
    timings["kv_index_rotate"] = t
    print(f"kv_index rotate (device remap, 4 shards): {t.median_us:.0f} us")
    csv_rows.append(f"kv_index_rotate,{t.median_us:.0f},4shards")

    # batched admission: ONE jitted device call per 64-fingerprint batch,
    # vs the pre-PR host loop (one install dispatch per fingerprint).
    # Fresh unique fingerprints every rep so the install path (not the
    # resident fast path) is what's timed.
    n_fp, n_batches = 64, reps + 4
    all_fps = (1 + np.arange(n_fp * n_batches, dtype=np.uint32))
    fp_batches = iter(np.split(all_fps, n_batches))
    idx_b = MonarchKVIndex(KVIndexConfig(n_sets=8, admit_after_reads=0))
    t = time_callable(lambda: idx_b.admit_fps(next(fp_batches)),
                      warmup=2, reps=reps)
    timings["kv_index_admit"] = t
    assert idx_b.stats.admit_calls == reps + 2   # O(1) calls per batch
    print(f"kv_index admit 64 fps (batched): {t.median_us:.0f} us "
          f"({t.median_us / n_fp:.1f} us/install)")
    csv_rows.append(f"kv_index_admit,{t.median_us:.0f},64fp")

    loop_batches = iter(np.split(all_fps + 1_000_000, n_batches))
    idx_l = MonarchKVIndex(KVIndexConfig(n_sets=8, admit_after_reads=0))
    t2 = time_callable(lambda: _admit_hostloop(idx_l, next(loop_batches)),
                       warmup=2, reps=reps)
    timings["kv_index_admit_hostloop"] = t2
    print(f"kv_index admit 64 fps (pre-PR host loop): {t2.median_us:.0f} us "
          f"-> batched speedup {t2.median_us / t.median_us:.1f}x")
    csv_rows.append(f"kv_index_admit_hostloop,{t2.median_us:.0f},"
                    f"{t2.median_us / t.median_us:.1f}x")

    # stacked admission at 4 set shards: STILL one device dispatch per
    # batch (round-grid shard_map over the ("sets",) mesh; collapsed to
    # the single donated scan on this 1-device rig), vs the kept
    # per-partition fanout oracle paying one dispatch per occupied
    # partition.
    st_batches = iter(np.split(all_fps + 4_000_000, n_batches))
    idx_st = MonarchKVIndex(KVIndexConfig(
        n_sets=8, n_shards=4, admit_after_reads=0))
    t = time_callable(lambda: idx_st.admit_fps(next(st_batches)),
                      warmup=2, reps=reps)
    timings["kv_index_admit_stacked"] = t
    assert idx_st.stats.admit_calls == reps + 2   # ONE dispatch per batch
    print(f"kv_index admit 64 fps, 4 set shards (stacked): "
          f"{t.median_us:.0f} us ({idx_st.stats.admit_calls} dispatches/"
          f"{reps + 2} batches)")
    csv_rows.append(f"kv_index_admit_stacked,{t.median_us:.0f},4shards")

    fan_batches = iter(np.split(all_fps + 5_000_000, n_batches))
    idx_fa = MonarchKVIndex(KVIndexConfig(
        n_sets=8, n_shards=4, admit_after_reads=0), dispatch="fanout")
    t2 = time_callable(lambda: idx_fa.admit_fps(next(fan_batches)),
                       warmup=2, reps=reps)
    timings["kv_index_admit_fanout"] = t2
    print(f"kv_index admit 64 fps, 4-shard fanout oracle: "
          f"{t2.median_us:.0f} us -> stacked speedup "
          f"{t2.median_us / t.median_us:.1f}x")
    csv_rows.append(f"kv_index_admit_fanout,{t2.median_us:.0f},"
                    f"{t2.median_us / t.median_us:.1f}x")

    # device-resident hopscotch insert (apps/hashtable.py device backend):
    # one donated device call per insert — windowed scatter + bounded
    # hop-chain while-loop — vs the numpy reference store.  32 inserts
    # per timed call; fresh keys every call, sized so no rehash occurs.
    from repro.apps.hashtable import HopscotchTable
    ins_per_call = 32
    ht_keys = iter(range(1, 1 + ins_per_call * (reps + 2) * 2))
    ht_dev = HopscotchTable(12, window=32, backend="device")

    def _insert_many(table):
        for _ in range(ins_per_call):
            table.insert(next(ht_keys), 7)

    t = time_callable(lambda: _insert_many(ht_dev), warmup=1, reps=reps)
    timings["hashtable_insert_device"] = t
    print(f"hashtable insert x{ins_per_call} (device backend): "
          f"{t.median_us:.0f} us ({t.median_us / ins_per_call:.1f} "
          f"us/insert)")
    csv_rows.append(f"hashtable_insert_device,{t.median_us:.0f},"
                    f"{ins_per_call}ins")

    ht_host = HopscotchTable(12, window=32, backend="host")
    t2 = time_callable(lambda: _insert_many(ht_host), warmup=1, reps=reps)
    timings["hashtable_insert_host"] = t2
    print(f"hashtable insert x{ins_per_call} (host reference): "
          f"{t2.median_us:.0f} us")
    csv_rows.append(f"hashtable_insert_host,{t2.median_us:.0f},"
                    f"{ins_per_call}ins")

    # async admission: a serving-loop step is admit(64 fps) + model
    # compute.  Inline pays admit + compute in series; behind the
    # AdmitQueue the worker drains the install WHILE the jitted compute
    # runs (XLA releases the GIL), so a window of steps should approach
    # max(sum compute, sum admit) — the admit latency is hidden.  Each
    # timed callable is a WHOLE window of steps plus (async) the drain
    # barrier: throughput, not per-step latency, because a single step's
    # cost depends on where the worker happens to be, which made the
    # per-step median a coin flip on a contended CPU.  Fresh unique
    # fingerprints every step, as in the batched-admit bench above.
    win_steps = 6
    w_proxy = jnp.asarray(rng.standard_normal((256, 256)).astype(np.float32))

    @jax.jit
    def _compute_proxy(x):
        for _ in range(4):
            x = jnp.tanh(x @ x)
        return x

    n_windows = reps + 1               # warmup=1
    n_async = n_fp * win_steps * n_windows * 2
    async_fps = 1 + np.arange(n_async, dtype=np.uint32) + 2_000_000
    half = n_async // 2
    inline_iter = iter(np.split(async_fps[:half], half // n_fp))
    queue_iter = iter(np.split(async_fps[half:], half // n_fp))

    idx_in = MonarchKVIndex(KVIndexConfig(n_sets=8, admit_after_reads=0))

    def window_inline():
        for _ in range(win_steps):
            idx_in.admit_fps(next(inline_iter))
            jax.block_until_ready(_compute_proxy(w_proxy))

    t_in = time_callable(window_inline, warmup=1, reps=reps)
    timings["kv_index_admit_inline"] = t_in

    idx_as = MonarchKVIndex(KVIndexConfig(n_sets=8, admit_after_reads=0))
    q = AdmitQueue(idx_as, background=True, read_your_writes=False)

    def window_async():
        for _ in range(win_steps):
            q.submit(next(queue_iter))
            jax.block_until_ready(_compute_proxy(w_proxy))
        q.flush()                      # window completes all its installs

    t_as = time_callable(window_async, warmup=1, reps=reps)
    q.close()
    timings["kv_index_admit_async"] = t_as
    hidden = (t_in.median_us - t_as.median_us) / win_steps
    print(f"kv_index admit 64 fps + compute x{win_steps}: "
          f"inline {t_in.median_us:.0f} us vs async {t_as.median_us:.0f} us"
          f" incl. drain ({hidden:.0f} us/step of admit latency hidden)")
    csv_rows.append(f"kv_index_admit_inline,{t_in.median_us:.0f},"
                    f"{win_steps}x64fp")
    csv_rows.append(f"kv_index_admit_async,{t_as.median_us:.0f},"
                    f"{win_steps}x64fp")

    # wear-op microbench: a 256-write trace through the donated device op
    # (the §8 accounting the admit pipeline fuses per install).
    wcfg = wear.WearConfig(n_supersets=64, m_writes=3, dc_limit=1 << 20,
                           t_mww_cycles=1 << 20)
    ss = rng.integers(0, 64, 256).astype(np.int32)
    dirty = rng.integers(0, 2, 256).astype(bool)
    cycles = np.arange(256, dtype=np.int32)
    wstate_box = [wear.init_state(wcfg)]

    def _wear_call():
        st, _, _ = wear.record_writes_device(
            wstate_box[0], wcfg, ss, dirty, cycles)
        wstate_box[0] = st
        return st.write_counter

    t = time_callable(_wear_call, warmup=2, reps=reps)
    timings["wear_record_batch"] = t
    print(f"wear record_writes 256-write trace: {t.median_us:.0f} us "
          f"({t.median_us / 256:.2f} us/write)")
    csv_rows.append(f"wear_record_batch,{t.median_us:.0f},256w")

    # roofline check: analytic HBM traffic per launch for the search
    # kernels (every operand + result touched once — the same byte terms
    # roofline/analysis.py uses), turned into achieved bytes/s at the
    # measured median and a fraction of the active machine's bandwidth
    # ceiling.  On this interpret-mode rig the fractions are tiny (the
    # interpreter, not the memory system, is the wall) — what the numbers
    # pin is the 8x plane-traffic drop from packing, which survives any
    # machine profile.
    from repro.roofline.analysis import current_machine
    machine = current_machine()
    q_ms, out_b = 128, 128 * 4
    kernel_bytes = {
        "xam_search": 64 * 64 * 2 + 64 * 512 + 64 * 4,
        "xam_multiset": q_ms * r * 2 + n_sets * (r * c + c) + out_b,
        "xam_multiset_packed":
            q_ms * r * 2 + n_sets * ((r // 8) * c + c) + out_b,
    }
    roofline = {"machine": machine.name, "hbm_bw": machine.hbm_bw,
                "kernels": {}}
    for name, nbytes in kernel_bytes.items():
        med_s = timings[name].median_us * 1e-6
        achieved = nbytes / med_s if med_s > 0 else 0.0
        frac = achieved / machine.hbm_bw
        roofline["kernels"][name] = {
            "hbm_bytes": nbytes,
            "achieved_bytes_per_s": round(achieved, 1),
            "roofline_fraction": frac,
        }
        print(f"roofline {name}: {nbytes} B/launch, "
              f"{achieved / 1e6:.1f} MB/s achieved "
              f"({frac:.2e} of {machine.name} HBM bw)")

    emit_json("kernels", {
        "reps": reps,
        "timings_us": {
            name: {"median": t.median_us, "best": t.best_us,
                   "mean": t.mean_us}
            for name, t in timings.items()},
        "kv_index_hit_rate": float(idx.hit_rate),
        "roofline": roofline,
    }, quick=quick)
