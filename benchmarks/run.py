"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
    PYTHONPATH=src python -m benchmarks.run --autotune   # block-shape cache

Modules (paper artifact -> bench):
    Table 1        -> table1_tech        (32KB block technology study, §5)
    Fig. 9/10      -> fig9_cache         (cache-mode perf + hit rates, C1-C4)
    Fig. 11        -> fig11_lifetime     (M=3 lifetime vs ideal leveling, C7/C8)
    Figs. 12-14    -> fig12_14_hashing   (hopscotch/YCSB flat-CAM, C5)
    §10.5          -> string_match       (Phoenix String-Match, C6)
    kernels        -> kernels_bench      (Pallas kernels us/call + KV index
                                          lookup/admit + wear-op microbench)
    front end      -> serve_bench        (open-loop request latency: Poisson
                                          + burst-trace arrivals, p50/p99,
                                          goodput, shed rate)
    decode path    -> decode_bench       (prefix-cache resume vs no-cache:
                                          decode tokens/s, hit rate,
                                          token-identity)
    §Roofline      -> roofline_summary   (dry-run three-term table)

Each module appends ``name,us_per_call,derived`` CSV rows; the combined CSV
lands in benchmarks/results.csv.  The figure modules additionally emit
machine-readable ``BENCH_<name>.json`` artifacts (see ``repro.bench``);
``--quick`` selects the CI-sized sweep policy from
``repro.bench.harness.BenchSizes``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from repro.bench import BenchSizes

from benchmarks import (decode_bench, fig9_cache, fig11_lifetime,
                        fig12_14_hashing, kernels_bench, roofline_summary,
                        serve_bench, string_match, table1_tech)

CSV_PATH = os.path.join(os.path.dirname(__file__), "results.csv")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="run a single module by name")
    ap.add_argument("--autotune", action="store_true",
                    help="regenerate the kernel block-shape cache "
                         "(src/repro/kernels/autotune_cache.json) instead "
                         "of running the benches")
    args = ap.parse_args(argv)
    if args.autotune:
        from repro.kernels import autotune
        payload = autotune.autotune(quick=args.quick)
        for key in sorted(payload["families"]):
            fam = payload["families"][key]
            shape = f"block_q={fam['block_q']}"
            if "block_c" in fam:
                shape += f" block_c={fam['block_c']}"
            print(f"[autotune] {key}: {shape} ({fam['median_us']} us)")
        print(f"[autotune] wrote {autotune.DEFAULT_CACHE_PATH} "
              f"(fingerprint {autotune.cache_fingerprint()})")
        return
    sizes = BenchSizes(quick=args.quick)

    benches = [
        ("table1_tech", lambda rows: table1_tech.run(rows)),
        ("kernels_bench", lambda rows: kernels_bench.run(
            rows, quick=args.quick)),
        ("fig9_cache", lambda rows: fig9_cache.run(
            rows, n_requests=sizes.fig_requests, systems=sizes.systems,
            quick=args.quick)),
        ("fig11_lifetime", lambda rows: fig11_lifetime.run(
            rows, n_requests=sizes.fig_requests, quick=args.quick)),
        ("fig12_14_hashing", lambda rows: fig12_14_hashing.run(
            rows, quick=args.quick)),
        ("serve_bench", lambda rows: serve_bench.run(rows, quick=args.quick)),
        ("decode_bench", lambda rows: decode_bench.run(
            rows, quick=args.quick)),
        ("string_match", lambda rows: string_match.run(rows)),
        ("roofline_summary", lambda rows: roofline_summary.run(rows)),
    ]
    if args.only and args.only not in {n for n, _ in benches}:
        ap.error(f"--only {args.only!r}: unknown module "
                 f"(choose from {', '.join(n for n, _ in benches)})")

    rows: list[str] = ["name,us_per_call,derived"]
    failures = []
    t_all = time.time()
    for name, fn in benches:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"\n{'=' * 72}\n[bench] {name}\n{'=' * 72}")
        try:
            fn(rows)
            print(f"[bench] {name} done in {time.time() - t0:.1f}s")
        except Exception as e:  # keep the harness going; report at the end
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    with open(CSV_PATH, "w") as f:
        f.write("\n".join(rows) + "\n")
    print(f"\n[bench] all done in {time.time() - t_all:.1f}s; "
          f"{len(rows) - 1} CSV rows -> {CSV_PATH}")
    if failures:
        print("[bench] FAILURES:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
