"""§10.5 reproduction: String-Match (Phoenix) in flat mode.

Monarch broadcasts searches covering 4 KB of data each, executed IN-SITU —
only match vectors cross the TSV interface.  Data must first be copied from
DDRx into the CAM arrays with 64-bit block alignment: a preprocessing pass
plus an 8x storage blow-up, both charged exactly as the paper does
(§10.5).  Baselines stream the resident dataset line-by-line to the CPU
for comparison — every byte crosses the interface, every line occupies a
bank, every probe is a dependent read.

Batch model: an iMDB serves a QUERY BATCH over the same corpus; the
Monarch copy-in is paid once per corpus, searches per pattern.  The paper
does not state its pattern count; we use P=32 (documented knob) — at P=1
the copy-in dominates and Monarch LOSES, which the benchmark also prints
(break-even analysis) because that is the honest shape of the tradeoff.

The Pallas kernel does the actual matching on a smaller corpus (numerical
correctness + us/call); the 500 MB working-set timing uses the op-count
model with Table 3 parameters.  Paper claims (C6): 14x / 12x / 11x / 24x
over RRAM / HBM-C / CMOS / HBM-SP.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import timing_model as tm
from repro.apps import stringmatch
from repro.core.timing import TECH_TIMING

WORKING_SET = 500 * 2 ** 20
N_PATTERNS = 32          # query batch amortizing the CAM copy-in


def _monarch_cycles(n: int, patterns: int) -> float:
    t = TECH_TIMING["monarch"]
    searches = patterns * (n // stringmatch.SEARCH_COVERAGE)
    copy_writes = n * stringmatch.BLOWUP // stringmatch.LINE
    ops = tm.OpCounts(
        chain_cycles=searches * tm.search_lat(t) / 64,  # 64 sets in flight
        searches=searches, writes=copy_writes,
        ddr_reads=n // stringmatch.LINE,
        bytes_to_cpu=n * stringmatch.BLOWUP          # copy-in crosses TSVs
        + searches * (stringmatch.SEARCH_COVERAGE // 64 // 8),  # match bits
        ddr_bytes=n,                                 # corpus out of DDR once
    )
    return tm.system_time_cycles(t, ops)


def _stream_cycles(tech: str, n: int, patterns: int, capacity: float,
                   tag_overhead: float = 1.0) -> float:
    t = TECH_TIMING[tech]
    ddr = TECH_TIMING["ddr4"]
    lines = n // stringmatch.LINE
    fit = min(1.0, capacity / n)
    rl = tm.read_lat(t) * tag_overhead
    per_pass_chain = lines * (fit * rl + (1 - fit) * tm.read_lat(ddr))
    ops = tm.OpCounts(
        chain_cycles=patterns * per_pass_chain,
        reads=patterns * lines * fit * tag_overhead,
        ddr_reads=patterns * lines * (1 - fit) + lines,  # spills + load
        bytes_to_cpu=patterns * n * fit,
        ddr_bytes=patterns * n * (1 - fit) + n,
    )
    return tm.system_time_cycles(t, ops)


def run(csv_rows: list[str]):
    # correctness + kernel timing on a real corpus
    corpus = stringmatch.make_corpus(1 << 20, seed=7)
    pat = bytes(corpus[12345:12345 + 12])
    t0 = time.time()
    rep = stringmatch.find(corpus, pat)
    us = (time.time() - t0) * 1e6
    print(f"\n== String-Match ==\nkernel: {rep.n_matches} matches in 1MiB, "
          f"{us:.0f}us/call (CPU interpret mode)")

    n = WORKING_SET
    results = {"monarch": _monarch_cycles(n, N_PATTERNS)}
    results["rram"] = _stream_cycles("rram_1r", n, N_PATTERNS, 8 * 2 ** 30)
    results["hbm-c"] = _stream_cycles("dram", n, N_PATTERNS, 4 * 2 ** 30,
                                      tag_overhead=1.5)
    results["hbm-sp"] = _stream_cycles("dram", n, N_PATTERNS, 4 * 2 ** 30)
    results["cmos"] = _stream_cycles("cmos", n, N_PATTERNS, 73 * 2 ** 20)

    base = results["monarch"]
    print(f"query batch P={N_PATTERNS} patterns over a resident 500 MB "
          f"corpus (copy-in charged once, 8x blow-up)")
    print(f"{'system':>8s} {'cycles':>14s} {'monarch_x':>10s}")
    for k, v in results.items():
        print(f"{k:>8s} {v:14.3e} {v / base:10.2f}")
    print("paper C6: RRAM 14x, HBM-C 12x, CMOS 11x, HBM-SP 24x")

    # break-even: how many patterns until the copy-in pays off vs HBM-SP?
    for p in (1, 2, 4, 8, 16, 32, 64):
        m = _monarch_cycles(n, p)
        b = _stream_cycles("dram", n, p, 4 * 2 ** 30)
        if b > m:
            print(f"break-even vs HBM-SP at P={p} patterns "
                  f"(below that the copy-in dominates and Monarch loses — "
                  f"the honest shape of the §10.5 tradeoff)")
            break
    for k in ("rram", "hbm-c", "cmos", "hbm-sp"):
        csv_rows.append(f"stringmatch_{k}_vs_monarch,0,{results[k] / base:.2f}")
    csv_rows.append(f"stringmatch_kernel,{us:.0f},{rep.n_matches}")
