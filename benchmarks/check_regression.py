"""CI perf/claims smoke: compare fresh ``BENCH_*.json`` artifacts against
the committed baselines and fail on large regressions.

    PYTHONPATH=src python -m benchmarks.run --quick --only kernels_bench
    PYTHONPATH=src python -m benchmarks.check_regression

Two kinds of coverage:

* ``kernels``: median timings.  A kernel regresses when ``current_median >
  threshold * baseline_median`` (default threshold 2.0 — interpret-mode
  medians on shared runners are noisy, so only a gross slowdown trips it).
* ``fig9`` / ``fig11``: the figure claims (speedups, lifetime-years
  medians, write-filter fractions) are MODEL OUTPUT, deterministic for a
  fixed quick sweep — they drift only when the simulator/wear semantics
  change.  Values are compared both ways against ``--fig-threshold``
  (default 1.05x), so an unintended durability-model change fails CI even
  when no kernel slowed down.

Two extra ``kernels`` gates beyond the per-entry thresholds:

* packed planes must pay off: the fresh ``xam_multiset_packed`` median
  must beat the COMMITTED ``xam_multiset`` baseline (the perf claim the
  packing PR makes; downgradable via ``BENCH_WARN_ONLY`` like any timing).
* the artifact must carry the roofline section (per-kernel ``hbm_bytes`` /
  ``achieved_bytes_per_s`` / positive ``roofline_fraction``) — structural,
  always fatal: losing it silently would unpin the bandwidth claims.

A third coverage leg, ``serve`` (``BENCH_serve.json`` from
``serve_bench``): the Poisson p50/p99 latencies are timings (threshold
plus ``BENCH_WARN_ONLY``, like the kernel medians), but the artifact's
SHAPE — >=2 offered-rate legs, each with latency/goodput/shed/hit
fields, plus the ``http`` network-edge leg with a sane
``transport_overhead_ms`` — is structural and always fatal, exactly
like the roofline section.

A fourth leg, ``decode`` (``BENCH_decode.json`` from ``decode_bench``):
the cached-vs-no-cache tokens/s are timings (threshold +
``BENCH_WARN_ONLY``), but the artifact's claims — both legs present with
sane throughput/hit fields, ``tokens_match`` true (resumed greedy decode
token-identical to full prefill), the cached leg actually hitting — are
structural and always fatal.

Artifacts present in only one file are reported but never fatal (new
benches land before their baseline is refreshed; a missing figure baseline
is skipped).  Set ``BENCH_WARN_ONLY=1`` to downgrade failures to warnings
on cold/shared runners; refresh a baseline by copying the emitted file
over ``benchmarks/baselines/BENCH_<name>.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE = os.path.join(HERE, "baselines", "BENCH_kernels.json")
DEFAULT_CURRENT = os.path.join(HERE, "BENCH_kernels.json")
FIG_BENCHES = ("fig9", "fig11")


def load_medians(path: str) -> dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    return {name: float(t["median"])
            for name, t in doc.get("timings_us", {}).items()}


def load_claims(path: str) -> dict[str, float]:
    """Numeric figure-claim values (the committed model-output medians)."""
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for k, v in doc.get("claims", {}).items():
        if isinstance(v, (int, float)):
            out[f"claims.{k}"] = float(v)
    # fig11 also pins the per-app lifetime medians
    for k, v in doc.get("years", {}).items():
        if isinstance(v, (int, float)):
            out[f"years.{k}"] = float(v)
    return out


def compare(baseline: dict[str, float], current: dict[str, float],
            threshold: float, *, two_sided: bool = False,
            unit: str = "us") -> tuple[list[str], list[str]]:
    """Returns (regressions, notes) as printable lines."""
    regressions, notes = [], []
    for name in sorted(set(baseline) | set(current)):
        if name not in current:
            notes.append(f"  {name}: in baseline only (bench removed?)")
            continue
        if name not in baseline:
            notes.append(f"  {name}: new bench ({current[name]:.0f} {unit}),"
                         " no baseline yet")
            continue
        ratio = current[name] / max(abs(baseline[name]), 1e-9)
        line = (f"  {name}: {current[name]:.4g} {unit} vs baseline "
                f"{baseline[name]:.4g} {unit} ({ratio:.2f}x)")
        bad = ratio > threshold or (two_sided and ratio < 1.0 / threshold)
        (regressions if bad else notes).append(line)
    return regressions, notes


def packed_gate(baseline: dict[str, float],
                current: dict[str, float]) -> list[str]:
    """The packing claim: the packed-plane multiset median beats int8.

    Two legs, both required: the SAME-RUN comparison (fresh packed vs
    fresh int8 — the bench times the pair interleaved, so this leg is
    robust to slow phases of a shared rig) and the cross-run comparison
    against the committed int8 baseline.  Empty list when both hold, or
    when a side is missing — new baselines land after the bench does."""
    cur = current.get("xam_multiset_packed")
    if cur is None:
        return []
    out = []
    peer = current.get("xam_multiset")
    if peer is not None and cur >= peer:
        out.append(f"  xam_multiset_packed: {cur:.4g} us does NOT beat "
                   f"the same-run xam_multiset {peer:.4g} us "
                   f"({cur / peer:.2f}x)")
    base = baseline.get("xam_multiset")
    if base is not None and cur >= base:
        out.append(f"  xam_multiset_packed: {cur:.4g} us does NOT beat "
                   f"the committed xam_multiset baseline {base:.4g} us "
                   f"({cur / base:.2f}x)")
    return out


SERVE_REQUIRED = ("offered_rps", "n_requests", "p50_ms", "p99_ms",
                  "goodput_rps", "shed_rate", "hit_rate")


def serve_structural_gate(doc: dict) -> list[str]:
    """Structural check on ``BENCH_serve.json`` — always fatal.

    The serving-front-end acceptance bar: Poisson legs at >= 2 distinct
    offered rates, each carrying the full latency/goodput/shed/hit field
    set with sane values.  Losing a field (or a rate point) silently
    would unpin the request-level SLO story."""
    legs = doc.get("poisson")
    if not isinstance(legs, list) or len(legs) < 2:
        return ["  serve.poisson: expected >=2 offered-rate legs, got "
                f"{legs if legs is None else len(legs)!r}"]
    bad = []
    for i, leg in enumerate(legs):
        for field in SERVE_REQUIRED:
            v = leg.get(field)
            if not isinstance(v, (int, float)):
                bad.append(f"  serve.poisson[{i}].{field}: {v!r} "
                           "(expected a number)")
        for field in ("shed_rate", "hit_rate"):
            v = leg.get(field)
            if isinstance(v, (int, float)) and not 0.0 <= v <= 1.0:
                bad.append(f"  serve.poisson[{i}].{field}: {v} "
                           "(expected a fraction in [0, 1])")
        p50, p99 = leg.get("p50_ms"), leg.get("p99_ms")
        if (isinstance(p50, (int, float)) and isinstance(p99, (int, float))
                and p50 > p99):
            bad.append(f"  serve.poisson[{i}]: p50 {p50} > p99 {p99}")
    rates = [leg.get("offered_rps") for leg in legs]
    if len(set(rates)) < 2:
        bad.append(f"  serve.poisson: offered rates {rates} are not "
                   ">=2 distinct points")
    # HTTP leg: the network edge must actually have been driven — same
    # field set as a Poisson leg plus the transport tax.
    http = doc.get("http")
    if not isinstance(http, dict):
        bad.append(f"  serve.http: {http!r} (expected the HTTP-leg "
                   "section — the socket path was not driven)")
        return bad
    for field in SERVE_REQUIRED + ("transport_overhead_ms",):
        v = http.get(field)
        if not isinstance(v, (int, float)):
            bad.append(f"  serve.http.{field}: {v!r} (expected a number)")
    ovh = http.get("transport_overhead_ms")
    if isinstance(ovh, (int, float)) and ovh < 0:
        bad.append(f"  serve.http.transport_overhead_ms: {ovh} (client "
                   "wall time cannot undercut server handling time)")
    p50, p99 = http.get("p50_ms"), http.get("p99_ms")
    if (isinstance(p50, (int, float)) and isinstance(p99, (int, float))
            and p50 > p99):
        bad.append(f"  serve.http: p50 {p50} > p99 {p99}")
    return bad


def serve_latencies(doc: dict) -> dict[str, float]:
    """p50/p99 per Poisson leg, keyed for :func:`compare` (timing gate:
    threshold-based, downgradable via ``BENCH_WARN_ONLY``)."""
    out = {}
    for leg in doc.get("poisson", []):
        rate = leg.get("offered_rps")
        for field in ("p50_ms", "p99_ms"):
            v = leg.get(field)
            if isinstance(v, (int, float)) and isinstance(rate, (int, float)):
                key = f"serve.{rate:g}rps.{field.removesuffix('_ms')}"
                out[key] = float(v) * 1e3            # ms -> us for compare
    return out


DECODE_LEG_REQUIRED = ("n_requests", "total_s", "tokens_per_s",
                       "prompt_tokens_per_s", "hit_rate",
                       "resumed_fraction")


def decode_structural_gate(doc: dict) -> list[str]:
    """Structural check on ``BENCH_decode.json`` — always fatal.

    The prefix-cache decode acceptance bar: both legs present with
    positive throughput, fractions in [0, 1], the cached leg actually
    hitting, and ``tokens_match`` true — the resumed decode emitted the
    SAME greedy tokens as the no-cache full prefill.  A false
    ``tokens_match`` means the restore path corrupted the KV cache;
    that must never be downgraded to a warning."""
    legs = doc.get("legs")
    if not isinstance(legs, dict):
        return [f"  decode.legs: {legs!r} (expected a dict)"]
    bad = []
    for name in ("no_cache", "cached"):
        leg = legs.get(name)
        if not isinstance(leg, dict):
            bad.append(f"  decode.legs.{name}: missing")
            continue
        for field in DECODE_LEG_REQUIRED:
            v = leg.get(field)
            if not isinstance(v, (int, float)):
                bad.append(f"  decode.legs.{name}.{field}: {v!r} "
                           "(expected a number)")
        for field in ("hit_rate", "resumed_fraction"):
            v = leg.get(field)
            if isinstance(v, (int, float)) and not 0.0 <= v <= 1.0:
                bad.append(f"  decode.legs.{name}.{field}: {v} "
                           "(expected a fraction in [0, 1])")
        for field in ("tokens_per_s", "total_s"):
            v = leg.get(field)
            if isinstance(v, (int, float)) and v <= 0:
                bad.append(f"  decode.legs.{name}.{field}: {v} "
                           "(expected > 0)")
    cached = legs.get("cached")
    if isinstance(cached, dict):
        hr = cached.get("hit_rate")
        if isinstance(hr, (int, float)) and hr <= 0:
            bad.append(f"  decode.legs.cached.hit_rate: {hr} (the cached "
                       "leg never hit — the bench is not exercising "
                       "resume)")
    sp = doc.get("speedup")
    if not isinstance(sp, (int, float)) or sp <= 0:
        bad.append(f"  decode.speedup: {sp!r} (expected a positive number)")
    if doc.get("tokens_match") is not True:
        bad.append(f"  decode.tokens_match: {doc.get('tokens_match')!r} "
                   "(resumed decode must be token-identical to full "
                   "prefill)")
    return bad


def decode_timings(doc: dict) -> dict[str, float]:
    """Per-leg wall time, keyed for :func:`compare` (timing gate:
    threshold-based, downgradable via ``BENCH_WARN_ONLY``)."""
    out = {}
    for name, leg in (doc.get("legs") or {}).items():
        v = leg.get("total_s") if isinstance(leg, dict) else None
        if isinstance(v, (int, float)):
            out[f"decode.{name}.total"] = float(v) * 1e6   # s -> us
    return out


def roofline_gate(path: str) -> list[str]:
    """Structural check on the roofline section of the current artifact."""
    with open(path) as f:
        doc = json.load(f)
    roof = doc.get("roofline")
    if not isinstance(roof, dict) or not roof.get("kernels"):
        return [f"  {os.path.basename(path)}: roofline section missing"]
    bad = []
    for name, entry in roof["kernels"].items():
        for field in ("hbm_bytes", "achieved_bytes_per_s",
                      "roofline_fraction"):
            v = entry.get(field)
            if not isinstance(v, (int, float)) or v <= 0:
                bad.append(f"  roofline.{name}.{field}: {v!r} "
                           "(expected a positive number)")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--current", default=DEFAULT_CURRENT)
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail when current_median > threshold * baseline")
    ap.add_argument("--fig-threshold", type=float, default=1.05,
                    help="two-sided drift bound for fig9/fig11 claim values")
    args = ap.parse_args(argv)

    warn_only = os.environ.get("BENCH_WARN_ONLY", "") not in ("", "0")
    try:
        base_medians = load_medians(args.baseline)
        cur_medians = load_medians(args.current)
    except FileNotFoundError as e:
        # A missing artifact is an operator error (benches not run /
        # wrong path), not a crash: say which file, what to do, and what
        # IS there, then fail the gate.
        found = sorted(
            f for d in {os.path.dirname(os.path.abspath(e.filename)), HERE}
            if os.path.isdir(d)
            for f in os.listdir(d) if f.startswith("BENCH_"))
        print(f"[perf-smoke] ERROR: artifact not found: {e.filename} — "
              "run `PYTHONPATH=src python -m benchmarks.run --quick --only "
              "kernels_bench` first (artifacts found: "
              f"{', '.join(found) if found else 'none'})")
        return 1
    regressions, notes = compare(base_medians, cur_medians, args.threshold)
    regressions += packed_gate(base_medians, cur_medians)
    print(f"[perf-smoke] baseline: {args.baseline}")
    print(f"[perf-smoke] current:  {args.current}")

    # Figure-claim drift is DETERMINISTIC model output — unlike the timing
    # medians it is immune to runner noise, so it stays fatal even under
    # BENCH_WARN_ONLY.
    fig_regressions: list[str] = []
    for fig in FIG_BENCHES:
        base_p = os.path.join(HERE, "baselines", f"BENCH_{fig}.json")
        cur_p = os.path.join(HERE, f"BENCH_{fig}.json")
        if not (os.path.exists(base_p) and os.path.exists(cur_p)):
            notes.append(f"  {fig}: artifact or baseline missing, skipped")
            continue
        r, n = compare(load_claims(base_p), load_claims(cur_p),
                       args.fig_threshold, two_sided=True, unit="")
        fig_regressions += [f"  [{fig}]{x.rstrip()}" for x in r]
        notes += [f"  [{fig}]{x.rstrip()}" for x in n]

    # Roofline structure is deterministic bench output — always fatal,
    # grouped with the claim checks.
    if os.path.exists(args.current):
        fig_regressions += roofline_gate(args.current)

    # Serving front end: structural gate on the fresh artifact (always
    # fatal), latency thresholds against the committed baseline (timing
    # — warn-only downgradable like the kernel medians).
    serve_cur = os.path.join(os.path.dirname(os.path.abspath(args.current))
                             if args.current != DEFAULT_CURRENT else HERE,
                             "BENCH_serve.json")
    serve_base = os.path.join(HERE, "baselines", "BENCH_serve.json")
    if os.path.exists(serve_cur):
        with open(serve_cur) as f:
            serve_doc = json.load(f)
        fig_regressions += serve_structural_gate(serve_doc)
        if os.path.exists(serve_base):
            with open(serve_base) as f:
                base_doc = json.load(f)
            r, n = compare(serve_latencies(base_doc),
                           serve_latencies(serve_doc), args.threshold)
            regressions += r
            notes += n
        else:
            notes.append("  serve: no committed baseline, latency "
                         "thresholds skipped")
    else:
        notes.append("  serve: artifact missing, skipped")

    # Prefix-cache decode path: same split — claims (token identity,
    # legs/fields present, cached leg hitting) always fatal; leg wall
    # times threshold-compared, warn-only downgradable.
    decode_cur = os.path.join(
        os.path.dirname(os.path.abspath(args.current))
        if args.current != DEFAULT_CURRENT else HERE, "BENCH_decode.json")
    decode_base = os.path.join(HERE, "baselines", "BENCH_decode.json")
    if os.path.exists(decode_cur):
        with open(decode_cur) as f:
            decode_doc = json.load(f)
        fig_regressions += decode_structural_gate(decode_doc)
        if os.path.exists(decode_base):
            with open(decode_base) as f:
                base_doc = json.load(f)
            r, n = compare(decode_timings(base_doc),
                           decode_timings(decode_doc), args.threshold)
            regressions += r
            notes += n
        else:
            notes.append("  decode: no committed baseline, timing "
                         "thresholds skipped")
    else:
        notes.append("  decode: artifact missing, skipped")

    for line in notes:
        print(line)
    if not regressions and not fig_regressions:
        print(f"[perf-smoke] OK: no kernel median regressed "
              f">{args.threshold:.1f}x, no figure claim drifted "
              f">{args.fig_threshold:.2f}x")
        return 0
    if regressions:
        print(f"[perf-smoke] REGRESSIONS (>{args.threshold:.1f}x median):")
        for line in regressions:
            print(line)
    if fig_regressions:
        print(f"[perf-smoke] CLAIM DRIFT (>{args.fig_threshold:.2f}x, "
              "deterministic — always fatal):")
        for line in fig_regressions:
            print(line)
    if warn_only and not fig_regressions:
        print("[perf-smoke] BENCH_WARN_ONLY set: reporting only, not "
              "failing (cold-runner mode)")
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
