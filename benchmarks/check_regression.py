"""CI perf smoke: compare a fresh ``BENCH_kernels.json`` against the
committed baseline and fail on large median regressions.

    PYTHONPATH=src python -m benchmarks.run --quick --only kernels_bench
    PYTHONPATH=src python -m benchmarks.check_regression

A kernel regresses when ``current_median > threshold * baseline_median``
(default threshold 2.0 — interpret-mode medians on shared runners are
noisy, so only a gross slowdown trips it).  Kernels present in only one
file are reported but never fatal (new benches land before their baseline
is refreshed).  Set ``BENCH_WARN_ONLY=1`` to downgrade failures to
warnings on cold/shared runners; refresh the baseline by copying the
emitted file over ``benchmarks/baselines/BENCH_kernels.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE = os.path.join(HERE, "baselines", "BENCH_kernels.json")
DEFAULT_CURRENT = os.path.join(HERE, "BENCH_kernels.json")


def load_medians(path: str) -> dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    return {name: float(t["median"])
            for name, t in doc.get("timings_us", {}).items()}


def compare(baseline: dict[str, float], current: dict[str, float],
            threshold: float) -> tuple[list[str], list[str]]:
    """Returns (regressions, notes) as printable lines."""
    regressions, notes = [], []
    for name in sorted(set(baseline) | set(current)):
        if name not in current:
            notes.append(f"  {name}: in baseline only (bench removed?)")
            continue
        if name not in baseline:
            notes.append(f"  {name}: new bench ({current[name]:.0f} us), "
                         "no baseline yet")
            continue
        ratio = current[name] / max(baseline[name], 1e-9)
        line = (f"  {name}: {current[name]:.0f} us vs baseline "
                f"{baseline[name]:.0f} us ({ratio:.2f}x)")
        if ratio > threshold:
            regressions.append(line)
        else:
            notes.append(line)
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--current", default=DEFAULT_CURRENT)
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail when current_median > threshold * baseline")
    args = ap.parse_args(argv)

    warn_only = os.environ.get("BENCH_WARN_ONLY", "") not in ("", "0")
    baseline = load_medians(args.baseline)
    current = load_medians(args.current)
    regressions, notes = compare(baseline, current, args.threshold)

    print(f"[perf-smoke] baseline: {args.baseline}")
    print(f"[perf-smoke] current:  {args.current}")
    for line in notes:
        print(line)
    if not regressions:
        print(f"[perf-smoke] OK: no kernel median regressed "
              f">{args.threshold:.1f}x")
        return 0
    print(f"[perf-smoke] REGRESSIONS (>{args.threshold:.1f}x median):")
    for line in regressions:
        print(line)
    if warn_only:
        print("[perf-smoke] BENCH_WARN_ONLY set: reporting only, not "
              "failing (cold-runner mode)")
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
