"""Table 1 reproduction (§5): 32KB building block across technologies, plus
the derived technology-selection figures of merit that justify picking
1R RRAM, SRAM+SCAM (CMOS) and DRAM as the 3D baselines and 2R XAM for
Monarch."""
from __future__ import annotations

from repro.core.timing import TABLE1


def run(csv_rows: list[str]):
    print("\n== Table 1: 32KB block, latency(ns)/energy(nJ)/area(mm2) ==")
    hdr = f"{'tech':>10s} {'rd_ns':>8s} {'wr_ns':>8s} {'srch_ns':>9s} " \
          f"{'rd_nj':>7s} {'wr_nj':>7s} {'srch_nj':>8s} {'area':>7s}"
    print(hdr)
    for name, r in TABLE1.items():
        print(f"{name:>10s} {r.read_ns:8.3f} {r.write_ns:8.3f} "
              f"{r.search_ns:9.3f} {r.read_nj:7.4f} {r.write_nj:7.4f} "
              f"{r.search_nj:8.4f} {r.area_mm2:7.4f}")

    # §5 claims to verify mechanically:
    xam, sram_scam, r1 = TABLE1["2R XAM"], TABLE1["SRAM+SCAM"], TABLE1["1R RAM"]
    checks = {
        "xam_area_10x_smaller_than_cmos": sram_scam.area_mm2 / xam.area_mm2,
        "xam_search_energy_best_rram": xam.search_nj
        < min(r1.search_nj, TABLE1["DRAM"].search_nj),
        "scam_fastest_search": TABLE1["SCAM"].search_ns
        <= min(v.search_ns for v in TABLE1.values()),
        "sram_write_10x_vs_dram": TABLE1["DRAM"].write_ns / TABLE1["SRAM"].write_ns,
    }
    print("derived:", checks)
    # search efficiency (1/(ns*nJ*mm2)) — XAM should lead the resistive pack
    fom = {n: 1.0 / (r.search_ns * r.search_nj * r.area_mm2)
           for n, r in TABLE1.items()}
    best_resistive = max(("1R RAM", "2T2R CAM", "1R+2T2R", "2R XAM"),
                         key=lambda n: fom[n])
    print(f"best resistive search FoM: {best_resistive}")
    csv_rows.append(f"table1_xam_area_ratio,0,{sram_scam.area_mm2 / xam.area_mm2:.2f}")
    csv_rows.append(f"table1_best_resistive_fom,0,{best_resistive}")
    assert best_resistive == "2R XAM"
    assert 8 < sram_scam.area_mm2 / xam.area_mm2 < 14   # "about 10x"
