"""§10.5 String-Match in flat-CAM mode: broadcast searches covering 4 KB
per command, with the copy-in preprocessing + 8x blow-up the paper charges.

    PYTHONPATH=src python examples/string_search.py [--mib 1]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.apps import stringmatch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mib", type=float, default=1.0)
    ap.add_argument("--pattern-len", type=int, default=12)
    args = ap.parse_args(argv)

    n = int(args.mib * 2 ** 20)
    corpus = stringmatch.make_corpus(n, seed=11)
    start = n // 3
    pattern = bytes(corpus[start:start + args.pattern_len])

    t0 = time.time()
    rep = stringmatch.find(corpus, pattern)
    dt = time.time() - t0
    print(f"corpus {args.mib} MiB, pattern {pattern!r}")
    print(f"matches: {rep.n_matches} in {dt:.2f}s "
          f"(Pallas kernel, interpret mode on CPU)")
    print(f"Monarch op counts: {rep.monarch_searches} search commands "
          f"(4 KB coverage each) after a copy-in of "
          f"{rep.monarch_copy_bytes / 2 ** 20:.0f} MiB (8x bit-plane "
          f"blow-up, charged as in §10.5)")
    print(f"baseline op counts: {rep.baseline_line_reads} 64 B line reads "
          f"streamed through the cache hierarchy")
    ratio = rep.baseline_line_reads / rep.monarch_searches
    print(f"request-count reduction: {ratio:.0f}x fewer memory commands")


if __name__ == "__main__":
    main()
