"""Serving driver: batched prefill+decode with the MonarchKVIndex prefix
cache — the paper's CAM-search + durability policies deployed where a real
serving stack uses them (vLLM-style prefix caching).

    PYTHONPATH=src python examples/serve_prefix_cache.py [--requests 24]

Requests share zipf-distributed prompt prefixes; the index answers "is
this 16-token chunk's KV already resident?" with ONE fused multi-set XAM
search per request batch, admits chunks under the no-allocate +
t_MWW-throttled policy, and rotates placement for wear evenness.  Prefill
skips the longest cached prefix.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer
from repro.serve import step as serve_step
from repro.serve.kv_index import CHUNK_TOKENS, KVIndexConfig, MonarchKVIndex


def make_requests(n, rng, vocab, n_prefixes=4, prefix_len=64, tail_len=32):
    """Zipf-shared prefixes + unique tails (chat-style traffic)."""
    prefixes = [rng.integers(1, vocab, prefix_len).astype(np.int32)
                for _ in range(n_prefixes)]
    reqs = []
    for _ in range(n):
        p = prefixes[min(int(rng.zipf(1.5)) - 1, n_prefixes - 1)]
        tail = rng.integers(1, vocab, tail_len).astype(np.int32)
        reqs.append(np.concatenate([p, tail]))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--decode-tokens", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = configs.get_arch("yi-9b").reduced()
    rng = np.random.default_rng(0)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    idx = MonarchKVIndex(KVIndexConfig(n_sets=8, admit_after_reads=1))

    reqs = make_requests(args.requests, rng, cfg.vocab_size)
    max_seq = len(reqs[0]) + args.decode_tokens
    prefill_fn = jax.jit(serve_step.make_prefill_step(cfg, max_seq))
    decode_fn = jax.jit(serve_step.make_decode_step(cfg))

    tokens_total, tokens_skipped = 0, 0
    t0 = time.time()
    for r, toks in enumerate(reqs):
        tok2d = toks[None, :]
        hits = idx.lookup(tok2d)[0]                      # per-chunk bools
        # longest cached prefix (contiguous leading hits)
        n_cached = 0
        for h in hits:
            if not h:
                break
            n_cached += 1
        skip = n_cached * CHUNK_TOKENS
        tokens_total += len(toks)
        tokens_skipped += skip
        # prefill the full prompt (cache-correctness) — a paged-attention
        # serving stack would materialize the cached chunks' KV instead of
        # recomputing them; the INDEX decision is what Monarch provides.
        batch = {"tokens": jnp.asarray(tok2d)}
        logits, cache = prefill_fn(params, batch)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for t in range(args.decode_tokens - 1):
            pos = jnp.asarray(len(toks) + t, jnp.int32)
            nxt, logits, cache = decode_fn(params, cache, nxt, pos)
        idx.admit(tok2d)                                 # offer for admission
    dt = time.time() - t0

    s = idx.stats
    print(f"[serve] {args.requests} requests, {args.decode_tokens} decode "
          f"tokens each, {dt:.1f}s total")
    print(f"[index] chunk hit rate {idx.hit_rate:.1%} "
          f"({s.chunk_hits}/{s.chunk_hits + s.chunk_misses}); "
          f"{s.searches} CAM searches")
    print(f"[index] prefix KV skippable: {tokens_skipped}/{tokens_total} "
          f"prompt tokens ({tokens_skipped / max(tokens_total, 1):.1%}) — "
          f"the prefill compute a paged serving stack avoids")
    print(f"[index] durability policy: {s.admissions} admissions, "
          f"{s.admission_skips} no-allocate skips, {s.throttled} t_MWW "
          f"throttles, {s.evictions} evictions, {s.rotations} rotations")
    print(f"[index] install distribution over sets: "
          f"{idx.write_distribution().tolist()}")


if __name__ == "__main__":
    main()
