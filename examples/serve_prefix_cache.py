"""Serving driver: batched prefill+decode with the MonarchKVIndex prefix
cache — the paper's CAM-search + durability policies deployed where a real
serving stack uses them (vLLM-style prefix caching).

    PYTHONPATH=src python examples/serve_prefix_cache.py [--requests 24]

Requests share zipf-distributed prompt prefixes; the index answers "is
this chunk's KV already resident?" with ONE fused multi-set XAM search
per request batch (chained PREFIX fingerprints — equal fingerprint means
equal entire prefix), admits chunks under the no-allocate +
t_MWW-throttled policy, and rotates placement for wear evenness.  A hit
is not just counted: the stored KV slabs are RESTORED into the decode
cache and prefill runs only over the suffix, from its RoPE offset —
decode then resumes token-identical to a full prefill
(``repro.serve.resume``; pinned by tests/test_decode_resume.py).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro import configs
from repro.launch.serve import run_request_loop
from repro.models import transformer
from repro.serve.admit_queue import AdmitQueue
from repro.serve.kv_index import (CHUNK_TOKENS, KVIndexConfig, KVSlabStore,
                                  MonarchKVIndex)
from repro.serve.resume import PrefixResumeEngine


def make_requests(n, rng, vocab, n_prefixes=4, prefix_len=64, tail_len=32):
    """Zipf-shared prefixes + unique tails (chat-style traffic)."""
    prefixes = [rng.integers(1, vocab, prefix_len).astype(np.int32)
                for _ in range(n_prefixes)]
    reqs = []
    for _ in range(n):
        p = prefixes[min(int(rng.zipf(1.5)) - 1, n_prefixes - 1)]
        tail = rng.integers(1, vocab, tail_len).astype(np.int32)
        reqs.append(np.concatenate([p, tail])[None, :])   # (1, S) batches
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--decode-tokens", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = configs.get_arch("yi-9b").reduced()
    rng = np.random.default_rng(0)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    # fingerprint="prefix": slab keys must identify the whole prefix.
    idx = MonarchKVIndex(
        KVIndexConfig(n_sets=8, admit_after_reads=1, fingerprint="prefix"),
        slab_store=KVSlabStore())
    admit_q = AdmitQueue(idx)

    reqs = make_requests(args.requests, rng, cfg.vocab_size)
    max_seq = reqs[0].shape[1] + args.decode_tokens
    engine = PrefixResumeEngine(params, cfg, max_seq=max_seq, index=idx,
                                decode_tokens=args.decode_tokens)
    prefill_fn, decode_fn = engine.request_fns()

    t0 = time.time()
    try:
        recs = run_request_loop(admit_q, reqs, prefill_fn=prefill_fn,
                                decode_fn=decode_fn)
    finally:
        admit_q.close()
    dt = time.time() - t0

    tokens_total = sum(r.chunks for r in recs) * CHUNK_TOKENS
    tokens_resumed = sum(r.resumed_chunks for r in recs) * CHUNK_TOKENS
    s = idx.stats
    print(f"[serve] {args.requests} requests, {args.decode_tokens} decode "
          f"tokens each, {dt:.1f}s total")
    print(f"[index] chunk hit rate {idx.hit_rate:.1%} "
          f"({s.chunk_hits}/{s.chunk_hits + s.chunk_misses}); "
          f"{s.searches} CAM searches")
    print(f"[index] prefix KV resumed: {tokens_resumed}/{tokens_total} "
          f"prompt tokens ({tokens_resumed / max(tokens_total, 1):.1%}) — "
          f"prefill compute actually skipped, decode bit-identical "
          f"(slab store {idx.slab_store.resident_bytes / 1e6:.2f} MB)")
    print(f"[index] durability policy: {s.admissions} admissions, "
          f"{s.admission_skips} no-allocate skips, {s.throttled} t_MWW "
          f"throttles, {s.evictions} evictions, {s.rotations} rotations")
    print(f"[index] install distribution over sets: "
          f"{idx.write_distribution().tolist()}")
    audit = idx.slab_lockstep_report()
    assert not audit["missing_slabs"] and not audit["orphan_slabs"], audit


if __name__ == "__main__":
    main()
