"""Fig. 6 reproduction: a key-value store on the flat-CAM/flat-RAM
scratchpads, then the same workload on the Hopscotch table whose lookup
path is ONE Monarch search per window (paper §9.2.2).

    PYTHONPATH=src python examples/kv_store.py
"""
from __future__ import annotations

import time

import numpy as np

from repro.apps.hashtable import HopscotchTable
from repro.core.api import MonarchDevice
from repro.data import pipeline


def fig6_flow():
    print("== Fig. 6: flat-CAM key-value store ==")
    dev = MonarchDevice(n_sets=8, key_bits=64, set_cols=64)
    keys = dev.flat_cam_malloc(64)    # myKEYS
    data = dev.flat_ram_malloc(64)    # myDATA
    rng = np.random.default_rng(1)
    stored = {}
    for i in range(64):
        k = int(rng.integers(1, 1 << 48))
        stored[k] = i * 10
        dev.cam_write(keys, i, k)     # write keys column-wise (ColumnIn CAM)
        dev.ram_write(data, i, i * 10)
    probe = list(stored)[17]
    t0 = time.time()
    v = dev.kv_lookup(keys, data, probe)
    print(f"lookup({probe:#x}) = {v} (expect {stored[probe]}) "
          f"in {(time.time() - t0) * 1e3:.1f} ms")
    n_search = sum(1 for c in dev.command_log if c.startswith("S "))
    print(f"commands: {n_search} search(es) for a 64-entry store "
          f"(baseline would serially read up to 64 words)\n")


def hopscotch_ycsb():
    print("== Hopscotch + YCSB-B (95% reads), Monarch search lookups ==")
    t = HopscotchTable(12, window=32)
    ycsb = pipeline.YcsbConfig(n_keys=2000, n_ops=4000, read_fraction=0.95)
    keys, is_read = pipeline.ycsb_ops(ycsb)
    # load phase
    for k in np.unique(keys[is_read]):
        t.insert(int(k), int(k) % 997)
    # run phase: batched CAM lookups for reads, inserts for writes
    t0 = time.time()
    r_keys = keys[is_read]
    vals, hits = t.lookup_monarch(r_keys)
    for k in keys[~is_read]:
        t.insert(int(k), 1)
    dt = time.time() - t0
    s = t.stats
    print(f"{len(r_keys)} lookups ({hits.mean():.1%} hit), "
          f"{(~is_read).sum()} inserts in {dt:.2f}s")
    print(f"op counts: searches={s.searches} (Monarch) vs probes the "
          f"baseline would issue serially; writes={s.writes}, "
          f"swaps={s.swaps}, rehashes={s.rehashes}")
    print(f"load factor {t.load:.2f}; window invariant holds -> every "
          f"lookup is ONE search command covering the whole window")


if __name__ == "__main__":
    fig6_flow()
    hopscotch_ycsb()
