"""Quickstart: the Monarch XAM primitive in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Build a XAM set (64 x 512 bit plane), store keys column-wise.
2. Run ONE masked CAM search over all 512 columns (the paper's §4.2.2
   operation; on TPU this is the MXU kernel in repro/kernels/xam_search).
3. Same flow through the user-space API (Fig. 6 key-value store).
"""
import numpy as np
import jax.numpy as jnp

from repro.core import xam
from repro.core.api import MonarchDevice
from repro.kernels.xam_search import ops as xam_ops


def main():
    rng = np.random.default_rng(7)

    # --- 1. raw XAM set -----------------------------------------------
    arr = xam.make_set()                       # 64 rows x 512 columns
    key = jnp.asarray(rng.integers(0, 2, 64), jnp.int8)
    arr = xam.store_key_colwise(arr, jnp.asarray(137), key)
    matches, idx = xam.set_search(arr, key, jnp.ones(64, jnp.int8))
    print(f"[xam]    stored the key at column 137; search found column "
          f"{int(idx)} ({int(matches.sum())} match)")

    # --- 2. batched MXU-kernel search ----------------------------------
    keys = rng.integers(0, 2, (8, 64)).astype(np.int8)     # 8 queries
    data = rng.integers(0, 2, (64, 512)).astype(np.int8)   # one set plane
    data[:, 42] = keys[3]                                  # plant a match
    hits = xam_ops.xam_search(keys, data)                  # Pallas kernel
    print(f"[kernel] query 3 matches columns "
          f"{np.nonzero(np.asarray(hits[3]))[0].tolist()}")

    # --- 3. Fig. 6 software flow ---------------------------------------
    dev = MonarchDevice(n_sets=4, key_bits=64, set_cols=8)
    keys_alloc = dev.flat_cam_malloc(16)
    data_alloc = dev.flat_ram_malloc(16)
    kv = {0xCAFE: 101, 0xBEEF: 202, 0xF00D: 303}
    for i, (k, v) in enumerate(kv.items()):
        dev.cam_write(keys_alloc, i, k)
        dev.ram_write(data_alloc, i, v)
    for k in (0xBEEF, 0xDEAD):
        print(f"[api]    kv_lookup(0x{k:X}) -> "
              f"{dev.kv_lookup(keys_alloc, data_alloc, k)}")
    # masked partial search: match on the high byte only
    print(f"[api]    masked lookup (key=0xF000, mask=0xFF00) -> "
          f"{dev.kv_lookup(keys_alloc, data_alloc, 0xF000, mask=0xFF00)}")
    print(f"[api]    command log: {dev.command_log[-4:]}")


if __name__ == "__main__":
    main()
