"""End-to-end training driver: a yi-family dense LM on the synthetic
pipeline, with checkpoint/restart, straggler watchdog, and loss logging.

Quick smoke (CPU, ~2 min):

    PYTHONPATH=src python examples/train_lm.py --steps 30

The ~100M-parameter run the assignment asks for (few hundred steps):

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Kill it at any point and rerun the same command — it restarts from the
latest published checkpoint (atomic-rename publish; see
repro/dist/checkpoint.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ArchConfig
from repro.data import pipeline
from repro.dist import checkpoint, straggler
from repro.models import transformer
from repro.train import optimizer as opt
from repro.train import step as train_step_mod

PRESETS = {
    # ~10M: CI-sized smoke model (yi topology, tiny dims).
    "10m": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, d_head=64,
                d_ff=704, vocab_size=8192),
    # ~100M-parameter dense LM (the assignment's end-to-end driver size).
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_head=64, d_ff=2048, vocab_size=32_000),
}


def build_config(preset: str) -> ArchConfig:
    base = configs.get_arch("yi-9b")
    return dataclasses.replace(base, name=f"yi-{preset}", **PRESETS[preset])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = build_config(args.preset)
    ocfg = opt.OptConfig(peak_lr=args.lr, warmup_steps=20,
                         total_steps=max(args.steps, 100))
    dcfg = pipeline.DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch, seed=0)

    state = train_step_mod.init_state(jax.random.PRNGKey(0), cfg)
    n_params = transformer.param_count(state["params"])
    print(f"[train] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.batch}x{args.seq} tokens/step, {args.steps} steps")

    start_step, restored = checkpoint.restore_latest(
        f"{args.ckpt_dir}/{args.preset}", state)
    if restored is not None:
        state = jax.tree.map(jnp.asarray, restored)
        print(f"[train] restored checkpoint at step {start_step}")
    start_step = start_step or 0

    step_fn = jax.jit(train_step_mod.make_train_step(cfg, ocfg),
                      donate_argnums=(0,))
    watchdog = straggler.StragglerWatchdog()

    tokens_per_step = args.batch * args.seq
    first_loss = None
    for step in range(start_step, args.steps):
        t0 = time.time()
        raw = pipeline.batch_at(dcfg, step)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if first_loss is None:
            first_loss = loss
        action = watchdog.observe(dt)
        if action != straggler.OK:
            print(f"[watchdog] step {step}: {dt:.1f}s -> {action}")
        if step % args.log_every == 0 or step == args.steps - 1:
            mfu_flops = 6 * n_params * tokens_per_step / dt
            print(f"[train] step {step:4d} loss {loss:7.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):6.2f} "
                  f"{dt:5.1f}s/step {mfu_flops / 1e9:6.1f} GFLOP/s")
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            d = checkpoint.save(f"{args.ckpt_dir}/{args.preset}", step + 1,
                                state)
            print(f"[ckpt]  published {d}")
    print(f"[train] done: loss {first_loss:.4f} -> {loss:.4f} "
          f"({'DOWN' if loss < first_loss else 'not down'})")
    return first_loss, loss


if __name__ == "__main__":
    main()
